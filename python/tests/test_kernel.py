"""L1 Pallas kernels vs pure-jnp oracles — the core correctness signal.

hypothesis sweeps shapes/activations; fixed cases pin the block-boundary
edge cases (exact multiples, off-by-one, tiny and wide shapes).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import (
    fused_matmul,
    mxu_utilization,
    vmem_bytes,
)
from compile.kernels.postprocess import decode_detections, head_meta
from compile.kernels.ref import ref_decode_detections, ref_fused_matmul

jax.config.update("jax_platform_name", "cpu")

RTOL = 2e-5
ATOL = 2e-5


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# fused_matmul
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 150),
    n=st.integers(1, 180),
    act=st.sampled_from(["none", "relu", "sigmoid"]),
)
def test_matmul_matches_ref_random_shapes(m, k, n, act):
    a, b = _rand(m * 7 + 1, m, k), _rand(k * 5 + 2, k, n)
    bias = _rand(n + 3, n)
    got = fused_matmul(a, b, bias, act=act)
    want = ref_fused_matmul(a, b, bias, act=act)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128),   # exact block
    (256, 128, 128),   # multiple blocks on M
    (129, 127, 130),   # off-by-one around the block edge
    (1, 1, 1),         # degenerate
    (1, 300, 1),       # long K reduction
    (300, 1, 300),     # rank-1 outer product
])
def test_matmul_block_boundaries(m, k, n):
    a, b, bias = _rand(1, m, k), _rand(2, k, n), _rand(3, n)
    np.testing.assert_allclose(
        fused_matmul(a, b, bias),
        ref_fused_matmul(a, b, bias),
        rtol=RTOL,
        atol=ATOL,
    )


@pytest.mark.parametrize("bm,bn,bk", [(64, 64, 64), (128, 128, 128), (32, 128, 64)])
def test_matmul_block_shape_invariance(bm, bn, bk):
    """Result must not depend on the chosen tiling."""
    a, b, bias = _rand(4, 100, 90), _rand(5, 90, 110), _rand(6, 110)
    base = fused_matmul(a, b, bias, act="relu")
    tiled = fused_matmul(a, b, bias, act="relu", block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(base, tiled, rtol=RTOL, atol=ATOL)


def test_matmul_relu_clamps_negatives():
    a = -jnp.ones((8, 8), jnp.float32)
    b = jnp.ones((8, 8), jnp.float32)
    bias = jnp.zeros((8,), jnp.float32)
    out = fused_matmul(a, b, bias, act="relu")
    assert float(jnp.min(out)) == 0.0


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        fused_matmul(jnp.zeros((2, 3)), jnp.zeros((4, 5)), jnp.zeros((5,)))
    with pytest.raises(ValueError):
        fused_matmul(jnp.zeros((2, 3)), jnp.zeros((3, 5)), jnp.zeros((4,)))
    with pytest.raises(ValueError):
        fused_matmul(
            jnp.zeros((2, 3)), jnp.zeros((3, 5)), jnp.zeros((5,)), act="gelu"
        )


def test_vmem_estimate_sane():
    # 128^3 f32 tiling: 3 tiles of 64 KiB + bias.
    assert vmem_bytes(128, 128, 128) == 4 * (3 * 128 * 128 + 128)


def test_mxu_utilization_prefers_fitting_blocks():
    # A 128-aligned GEMM wastes nothing; padding to 256 wastes issue slots.
    full = mxu_utilization(128, 128, 128, 128, 128, 128)
    padded = mxu_utilization(130, 130, 130, 128, 128, 128)
    assert full == 1.0
    assert padded < 0.2  # 130^3 useful of 256^3 issued


# ---------------------------------------------------------------------------
# decode_detections
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 6),
    grid=st.sampled_from([4, 6, 8, 10]),
    classes=st.integers(1, 6),
)
def test_decode_matches_ref(n, grid, classes):
    anchors = [[10, 14], [23, 27], [37, 58]]
    meta = head_meta(grid, anchors)
    boxes = grid * grid * len(anchors)
    head = _rand(n * 31 + grid, n, boxes, 5 + classes) * 3.0
    np.testing.assert_allclose(
        decode_detections(head, meta, stride=16),
        ref_decode_detections(head, meta, stride=16),
        rtol=RTOL,
        atol=ATOL,
    )


def test_decode_extreme_logits_stay_finite():
    meta = head_meta(4, [[10, 14]])
    head = jnp.full((2, 16, 9), 1e4, jnp.float32)
    out = decode_detections(head, meta)
    assert bool(jnp.all(jnp.isfinite(out)))
    # Scores saturate to 1, not beyond.
    assert float(jnp.max(out[..., 4:])) <= 1.0 + 1e-6


def test_decode_centers_inside_image():
    grid, stride = 6, 16
    meta = head_meta(grid, [[12, 16], [28, 36], [60, 80]])
    head = _rand(77, 3, grid * grid * 3, 9) * 5.0
    out = decode_detections(head, meta, stride=stride)
    assert float(jnp.min(out[..., 0])) >= 0.0
    assert float(jnp.max(out[..., 0])) <= grid * stride
    assert float(jnp.min(out[..., 1])) >= 0.0
    assert float(jnp.max(out[..., 1])) <= grid * stride


def test_head_meta_layout():
    meta = head_meta(2, [[3, 4], [5, 6]])
    assert meta.shape == (8, 4)
    # First two rows: cell (0,0) with both anchors.
    np.testing.assert_allclose(meta[0], [0, 0, 3, 4])
    np.testing.assert_allclose(meta[1], [0, 0, 5, 6])
    # Anchor table tiles across cells.
    np.testing.assert_allclose(meta[2][2:], [3, 4])


def test_decode_rejects_bad_meta():
    meta = head_meta(4, [[10, 14]])
    with pytest.raises(ValueError):
        decode_detections(jnp.zeros((1, 99, 9)), meta)
    with pytest.raises(ValueError):
        decode_detections(jnp.zeros((99, 9)), meta)
