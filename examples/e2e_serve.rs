//! End-to-end validation driver (DESIGN.md §6): loads the real AOT
//! artifacts through PJRT, stands up the serving stack (router → dynamic
//! batchers → executor), streams synthetic camera traffic through the full
//! traffic pipeline (detector → classifier/embedder fanout, like Fig. 2),
//! and reports effective throughput + latency percentiles.
//!
//! Python is NOT involved: the binary reads `artifacts/*.hlo.txt` only.
//!
//! Run: `make artifacts && cargo run --release --example e2e_serve`
//! Env:  E2E_SECONDS (default 10), E2E_FPS (default 30)

use std::collections::HashMap;
use std::time::Instant;

use octopinf::ensure;
use octopinf::runtime::default_artifacts_dir;
use octopinf::serving::{serve, ModelServeCfg, Request, Response};
use octopinf::util::error::Result;
use octopinf::util::table::{fnum, Table};
use octopinf::util::Rng;

fn main() -> Result<()> {
    let seconds: f64 = std::env::var("E2E_SECONDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10.0);
    let fps: f64 = std::env::var("E2E_FPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30.0);
    let slo_ms = 200.0; // traffic pipeline SLO

    let dir = default_artifacts_dir();
    ensure!(
        dir.join("manifest.tsv").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    // CWD-style serving configuration: detector batches moderately with a
    // tight wait bound; crop models batch deeper (burstier arrivals fill
    // them fast — Insight 1).
    let mut cfgs = HashMap::new();
    // profile-driven: CPU det_m is super-linear in batch, so batch 2
    cfgs.insert("det_m".into(), ModelServeCfg::new(2, 20.0));
    cfgs.insert("classifier".into(), ModelServeCfg::new(8, 15.0));
    cfgs.insert("embedder".into(), ModelServeCfg::new(8, 15.0));

    let (req_tx, req_rx) = std::sync::mpsc::channel::<Request>();
    let (resp_tx, resp_rx) = std::sync::mpsc::channel::<Response>();

    // Camera thread: frames at `fps`; each frame fans out Poisson(5)
    // crops to the classifier (65 %) / embedder (35 %), mirroring the
    // traffic pipeline's routing.
    let producer = std::thread::spawn(move || {
        let mut rng = Rng::new(2025);
        let frame_px = 128 * 128 * 3;
        let crop_px = 32 * 32 * 3;
        let mut id = 0u64;
        let n_frames = (seconds * fps) as u64;
        for _ in 0..n_frames {
            let t0 = Instant::now();
            id += 1;
            let _ = req_tx.send(Request {
                id,
                model: "det_m".into(),
                data: (0..frame_px).map(|_| rng.f64() as f32).collect(),
                slo_ms,
                tenant: 0,
                stream: 0,
                submitted: Instant::now(),
            });
            for _ in 0..rng.poisson(5.0) {
                id += 1;
                let model = if rng.chance(0.65) { "classifier" } else { "embedder" };
                let _ = req_tx.send(Request {
                    id,
                    model: model.into(),
                    data: (0..crop_px).map(|_| rng.f64() as f32).collect(),
                    slo_ms,
                    tenant: 0,
                    stream: id,
                    submitted: Instant::now(),
                });
            }
            if let Some(rest) =
                std::time::Duration::from_secs_f64(1.0 / fps).checked_sub(t0.elapsed())
            {
                std::thread::sleep(rest);
            }
        }
    });

    // Response drain (per-model stats).
    let drain = std::thread::spawn(move || {
        let mut per_model: HashMap<String, u64> = HashMap::new();
        while let Ok(r) = resp_rx.recv() {
            *per_model.entry(r.model).or_default() += 1;
        }
        per_model
    });

    println!("serving {} s of {} fps camera traffic through PJRT...", seconds, fps);
    let report = serve(&dir, &cfgs, req_rx, resp_tx)?;
    producer.join().unwrap();
    let delivered = drain.join().unwrap();

    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["requests served".to_string(), report.served.to_string()]);
    t.row(vec!["on-time (SLO 200ms)".into(), report.on_time.to_string()]);
    t.row(vec!["SLO attainment".into(), fnum(report.slo_attainment(), 3)]);
    t.row(vec![
        "effective throughput (req/s)".into(),
        fnum(report.effective_throughput(), 1),
    ]);
    t.row(vec!["latency p50 (ms)".into(), fnum(report.latency.p50(), 2)]);
    t.row(vec!["latency p95 (ms)".into(), fnum(report.latency.p95(), 2)]);
    t.row(vec!["latency p99 (ms)".into(), fnum(report.latency.p99(), 2)]);
    println!("{}", t.to_markdown());

    let mut bt = Table::new(vec!["model", "completions"]);
    let mut models: Vec<_> = delivered.iter().collect();
    models.sort();
    for (m, n) in models {
        bt.row(vec![m.clone(), n.to_string()]);
    }
    println!("\n{}", bt.to_markdown());

    let mut ht = Table::new(vec!["batch_size", "batches"]);
    let mut sizes: Vec<_> = report.batch_hist.iter().collect();
    sizes.sort();
    for (s, n) in sizes {
        ht.row(vec![s.to_string(), n.to_string()]);
    }
    println!("\n{}", ht.to_markdown());
    Ok(())
}
