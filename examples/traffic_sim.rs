//! Domain example: a city traffic-monitoring deployment — 6 traffic + 3
//! surveillance cameras on the paper testbed under 5G uplinks — comparing
//! all four systems end to end (the Fig. 6 scenario as a library client).
//!
//! Run: `cargo run --release --example traffic_sim [minutes]`

use octopinf::config::ExperimentConfig;
use octopinf::coordinator::SchedulerKind;
use octopinf::sim::{run, Scenario};
use octopinf::util::table::{fnum, Table};

fn main() {
    let minutes: f64 = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(10.0);
    let cfg = ExperimentConfig {
        duration_ms: minutes * 60_000.0,
        ..Default::default()
    };
    println!("simulating {minutes} min, 9 cameras, 5G uplinks, SLO 200/300 ms\n");

    let sc = Scenario::build(cfg);
    let mut t = Table::new(vec![
        "system",
        "effective(obj/s)",
        "total(obj/s)",
        "violation%",
        "p50(ms)",
        "p95(ms)",
        "memory(MB)",
        "gpu_util%",
    ]);
    for kind in SchedulerKind::all_main() {
        let m = run(&sc, kind);
        t.row(vec![
            kind.label().to_string(),
            fnum(m.effective_throughput(), 1),
            fnum(m.total_throughput(), 1),
            fnum(100.0 * m.violation_rate(), 1),
            fnum(m.latency.p50(), 1),
            fnum(m.latency.p95(), 1),
            fnum(m.peak_memory_mb, 0),
            fnum(100.0 * m.mean_gpu_util, 1),
        ]);
    }
    println!("{}", t.to_markdown());
}
