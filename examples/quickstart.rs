//! Quickstart: build the paper's testbed, run CWD + CORAL once, and print
//! the resulting deployment plan — the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`

use octopinf::cluster::Cluster;
use octopinf::coordinator::controller::make_scheduler;
use octopinf::coordinator::{SchedEnv, SchedulerKind};
use octopinf::pipeline::{surveillance_pipeline, traffic_pipeline};
use octopinf::profiles::ProfileStore;
use octopinf::util::table::Table;

fn main() {
    // 1. The cluster: 1 server (4 GPUs) + 9 Jetson-class edge devices.
    let cluster = Cluster::paper_testbed();

    // 2. Two EVA pipelines (Fig. 2), sourced on edge devices 1 and 2.
    let pipelines = vec![traffic_pipeline(1, 15.0), surveillance_pipeline(2, 15.0)];

    // 3. Profiles + a bandwidth snapshot form the scheduling environment.
    let profiles = ProfileStore::analytic();
    let env = SchedEnv::bootstrap(
        &cluster,
        &profiles,
        &pipelines,
        vec![25.0; cluster.devices.len()], // 25 Mbit/s uplinks
    );

    // 4. Run the OctopInf controller (CWD + CORAL).
    let mut scheduler = make_scheduler(SchedulerKind::OctopInf, 42);
    let plan = scheduler.plan(&env);

    // 5. Inspect the plan.
    let mut t = Table::new(vec![
        "pipeline", "model", "device", "batch", "instances", "reserved_portions",
    ]);
    for a in &plan.assignments {
        let dag = &pipelines[a.pipeline];
        t.row(vec![
            dag.name.clone(),
            dag.models[a.model].spec.name.clone(),
            cluster.device(a.cfg.device).name.clone(),
            a.cfg.batch.to_string(),
            a.cfg.instances.to_string(),
            a.bindings
                .iter()
                .filter(|b| b.temporal.is_some())
                .count()
                .to_string(),
        ]);
    }
    println!("{}", t.to_markdown());
    println!(
        "\nsplits: traffic={} surveillance={}  unplaced={}  memory={:.0} MB",
        plan.split_points(0, &pipelines[0]),
        plan.split_points(1, &pipelines[1]),
        plan.unplaced,
        plan.total_memory_mb(&pipelines),
    );
}
