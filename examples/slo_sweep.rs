//! Domain example: SLO-sensitivity sweep (the Fig. 9 experiment as a
//! library client) — tighten pipeline SLOs in 25 ms steps and watch each
//! system's effective throughput degrade.
//!
//! Run: `cargo run --release --example slo_sweep [minutes]`

use octopinf::config::ExperimentConfig;
use octopinf::coordinator::SchedulerKind;
use octopinf::sim::{run, Scenario};
use octopinf::util::table::{fnum, Table};

fn main() {
    let minutes: f64 = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(6.0);
    let mut t = Table::new(vec![
        "slo_reduction(ms)",
        "octopinf",
        "distream",
        "jellyfish",
        "rim",
    ]);
    for red in [0.0, 25.0, 50.0, 75.0, 100.0] {
        let cfg = ExperimentConfig {
            slo_reduction_ms: red,
            duration_ms: minutes * 60_000.0,
            ..Default::default()
        };
        let sc = Scenario::build(cfg);
        let row: Vec<String> = SchedulerKind::all_main()
            .iter()
            .map(|&k| fnum(run(&sc, k).effective_throughput(), 1))
            .collect();
        let mut cells = vec![format!("-{red}")];
        cells.extend(row);
        t.row(cells);
        eprintln!("  swept -{red} ms");
    }
    println!("{}", t.to_markdown());
}
